"""Paper Section IV-D (Fig. 7): the D(M)-Krasulina family estimating the top
eigenvector of a streaming covariance (d=10, eigengap 0.1) — exact averaging,
gossip consensus through the MixOp engine, and the full streaming engine
(governed splitter -> prefetch ring -> K-round superstep -> closed-loop
governor) driving the PCA workload.

Run:  PYTHONPATH=src python examples/streaming_pca_dmkrasulina.py
"""
import jax
import jax.numpy as jnp

from repro.configs.base import AveragingConfig, GovernorConfig, StreamConfig
from repro.configs.paper_pca import FIG7, PCARunConfig
from repro.core import krasulina, problems
from repro.data.synthetic import make_pca_host_sampler, make_pca_stream
from repro.kernels import ops
from repro.train.driver import EngineConfig, StreamingDriver

stream = make_pca_stream(FIG7)
metric = lambda w: problems.pca_excess_risk(w, stream.cov, stream.lambda1)
w0 = jax.random.normal(jax.random.PRNGKey(0), (FIG7.dim,))
w0 = w0 / jnp.linalg.norm(w0)

print("Fig 7(a): excess risk vs B at t' = 1e5 samples (exact averaging)")
for B in (1, 10, 100, 1000):
    res = krasulina.run_dm_krasulina(
        stream.draw, w0, N=min(10, B), B=B, steps=max(1, 100_000 // B),
        stepsize=lambda t: 10.0 / t, trace_metric=metric)
    print(f"  B={B:5d}  excess risk = {float(res.trace_metric[-1]):.6f}")

print("Fig 7(b): mu discards at (N,B)=(10,100)")
for mu in (0, 10, 100, 1000):
    res = krasulina.run_dm_krasulina(
        stream.draw, w0, N=10, B=100, mu=mu, steps=1000,
        stepsize=lambda t: 10.0 / t, trace_metric=metric, seed=1)
    print(f"  mu={mu:5d}  excess risk = {float(res.trace_metric[-1]):.6f}")

print("gossip-averaged D-Krasulina (ring consensus on the xi's) vs exact:")
for avg in (None,
            AveragingConfig(mode="gossip", rounds=2),
            AveragingConfig(mode="gossip", rounds=8)):
    res = krasulina.run_d_krasulina(
        stream.draw, w0, N=10, B=100, steps=1000,
        stepsize=lambda t: 10.0 / t, averaging=avg, trace_metric=metric, seed=1)
    name = "exact (oracle)" if avg is None else f"gossip R={avg.rounds}"
    print(f"  {name:15s}  excess risk = {float(res.trace_metric[-1]):.6f}")

# the full streaming engine on the PCA workload: the governed splitter deals
# B samples per round, the prefetch ring stages {"z"} batches, the K-round
# superstep scans on device, and the ADAPTIVE governor re-plans (B, mu) from
# measured rates — B moves between pre-compiled buckets (plan swap, zero
# retrace) while the online estimator closes the loop on R_c
# (docs/DESIGN.md §Adaptive batch buckets)
run_cfg = PCARunConfig(
    pca=FIG7, averaging=AveragingConfig(mode="gossip", rounds=4),
    stream=StreamConfig(streaming_rate=1e4, processing_rate=1e6,
                        comms_rate=1e6))
N = 10
builder = krasulina.krasulina_superstep_builder(
    run_cfg.averaging, N, lambda t: 10.0 / t, metric=metric)
state = krasulina.init_krasulina_state(w0, run_cfg.averaging, N)
gov = GovernorConfig(buckets=(50, 100, 200), hysteresis=2)
with StreamingDriver(run_cfg, None, state, make_pca_host_sampler(stream),
                     superstep_builder=builder, n_nodes=N, batch=100,
                     engine=EngineConfig(superstep=8, prefetch_depth=2,
                                         governor=gov)) as drv:
    state, history = drv.run(25)
    print("driver (gossip R=4, K=8) governor decisions:")
    for rec in history:
        decision = ""
        if "bucket_switch" in rec:
            a, b = rec["bucket_switch"]
            decision += f"  SWITCH B:{a}->{b}"
        if "est_Rc" in rec:
            rc = rec["est_Rc"]
            decision += "  est_Rc=inf" if rc <= 0 else f"  est_Rc={rc:.3g}"
        if rec["superstep"] % 8 == 0 or decision:
            p = rec.get("replanned", rec["plan"])
            print(f"  superstep {rec['superstep']:3d}  B={rec['bucket']:4d} "
                  f"mu={p.mu:4d} {p.regime:17s} "
                  f"excess risk={rec['metrics']['metric']:.4f}{decision}")
    print(f"  buckets compiled: {list(drv.compiled_buckets)} "
          f"(ladder {list(drv.ladder.buckets)})")
first, last = history[0], history[-1]
print(f"driver (gossip R=4, K=8): excess risk "
      f"{first['metrics']['metric']:.4f} -> {last['metrics']['metric']:.4f}, "
      f"consensus spread {last['metrics']['consensus_err']:.2e}, "
      f"{last['samples_per_s']:.0f} samples/s, plan mu={drv.pipeline.plan.mu}")

# the fused TPU kernels compute the same answers (interpret mode on CPU):
z = stream.draw(jax.random.PRNGKey(2), 256)
xi_kernel = ops.krasulina_xi(w0, z, force_pallas=True)
xi_ref = problems.krasulina_xi(w0, z)
print(f"Pallas xi kernel max |xi - ref| = "
      f"{float(jnp.max(jnp.abs(xi_kernel - xi_ref))):.2e}")
import repro.core.mixing as mixing
sched = mixing.schedule("ring", N)
zn = stream.draw(jax.random.PRNGKey(3), 40).reshape(N, 4, -1)
wn = jnp.tile(w0[None], (N, 1))
h_kernel = ops.krasulina_xi_gossip(wn, zn, sched, 4, force_pallas=True)
h_ref = ops.krasulina_xi_gossip(wn, zn, sched, 4)
print(f"Pallas xi+gossip kernel max |h - ref| = "
      f"{float(jnp.max(jnp.abs(h_kernel - h_ref))):.2e}")
