"""Paper Section V (Fig. 9): D-SGD and AD-SGD with inexact consensus averaging
on a 6-regular random expander vs exact-averaging (centralized-equivalent) and
local-SGD baselines; plus the consensus-round trade-off R vs excess risk.

Run:  PYTHONPATH=src python examples/gossip_vs_exact.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_logreg import FIG9
from repro.core import dmb, dsgd, mixing, problems
from repro.data.synthetic import make_logreg_stream

stream = make_logreg_stream(FIG9)
grad = lambda w, x, y: problems.logistic_grad(w, x, y)
xe, ye = stream.draw(jax.random.PRNGKey(99), 30_000)
bayes = problems.logistic_loss(stream.w_star, xe, ye)
metric = lambda w: problems.logistic_loss(w, xe, ye) - bayes
w0 = jnp.zeros(FIG9.dim + 1)

N = 16
A = jnp.asarray(mixing.random_regular_expander(N, deg=6, seed=0))
print(f"6-regular expander on {N} nodes: lambda_2 = {mixing.lambda2(np.asarray(A)):.3f}")

B, steps = 64, 200
runs = {
    "centralized": dmb.run_dmb(grad, stream.draw, w0, N=1, B=B, steps=steps,
                               stepsize=lambda t: 2.5 / jnp.sqrt(t),
                               trace_metric=metric, seed=3),
    "local SGD": dsgd.run_local_sgd(grad, stream.draw, w0, N=N, B=B, steps=steps,
                                    stepsize=lambda t: 2.5 / jnp.sqrt(t),
                                    trace_metric=metric, seed=3),
}
for R in (1, 2, 8):
    runs[f"D-SGD R={R}"] = dsgd.run_dsgd(
        grad, stream.draw, w0, A, B=B, rounds=R, steps=steps,
        stepsize=lambda t: 2.5 / jnp.sqrt(t), trace_metric=metric, seed=3)
runs["AD-SGD R=8"] = dsgd.run_dsgd(
    grad, stream.draw, w0, A, B=B, rounds=8, steps=steps,
    stepsize=lambda t: 0.05 * (t + 1.0) / 2.0, accelerated=True,
    trace_metric=metric, seed=3,
    project=lambda w: problems.project_ball(w, 10.0))

print(f"{'method':14s} excess risk after {steps * B} samples")
for name, res in runs.items():
    print(f"  {name:14s} {float(res.trace_metric[-1]):.5f}")
