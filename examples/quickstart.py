"""Quickstart: the paper's framework in 60 lines.

1. Provision a distributed streaming system with the rate planner (eq. 3-4).
2. Train a model on the governed stream with DMB (exact averaging).
3. Switch the averaging mode to gossip consensus (D-SGD) — one config change.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.configs.base import AveragingConfig, RunConfig, SHAPES, StreamConfig
from repro.core.rates import plan
from repro.data.lm import MarkovTokenStream
from repro.launch.mesh import make_host_mesh, n_data_nodes
from repro.launch.sharding import activation_rules
from repro.models.common import mesh_rules
from repro.train.trainer import build_train_step, init_state

# --- 1. the rate model: can 8 nodes keep up with 1e5 samples/s? -------------
stream = StreamConfig(streaming_rate=1e5, processing_rate=5e4, comms_rate=1e4)
p = plan(stream, N=8, R=2)
print(f"planner: B={p.B}, mu={p.mu}, R_e={p.Re:.1f} mini-batches/s ({p.regime})")

# --- 2. DMB training on a reduced assigned architecture ---------------------
cfg = reduced(get_config("granite-8b"))
mesh = make_host_mesh()
for mode, rounds in (("exact", 1), ("gossip", 4)):
    run = RunConfig(model=cfg, shape=SHAPES["train_4k"],
                    averaging=AveragingConfig(mode=mode, rounds=rounds),
                    optimizer="adam", learning_rate=1e-3, param_dtype="float32")
    n_nodes = n_data_nodes(mesh)
    data = MarkovTokenStream(cfg.vocab_size).batches(batch=8, seq=128, seed=1)

    with mesh_rules(mesh, activation_rules(mesh, run.shape, mode != "exact")):
        state = init_state(run, jax.random.PRNGKey(0))
        if mode != "exact":
            from repro.train.trainer import make_node_batch, replicate_for_nodes
            state = replicate_for_nodes(state, n_nodes)
        step, _ = build_train_step(run, mesh)
        step = jax.jit(step, donate_argnums=0)
        losses = []
        for i, batch in zip(range(20), data):
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            if mode != "exact":
                batch = make_node_batch(batch, n_nodes)
            state, metrics = step(state, batch)
            losses.append(float(metrics["loss"]))
        print(f"{mode:6s}: loss {losses[0]:.3f} -> {losses[-1]:.3f} "
              f"(consensus_err {float(metrics['consensus_err']):.2e})")
        assert losses[-1] < losses[0], "training must reduce loss"
print("quickstart OK")
