"""Paper Section IV-B (Fig. 6): the DMB algorithm training a binary linear
classifier from a fast synthetic stream, in both the resourceful and the
under-provisioned (mu > 0 discards) regimes — then the same workload on the
full streaming engine with the adaptive-B governor (bucket ladder + online
(R_p, R_c) estimation, docs/DESIGN.md §Adaptive batch buckets).

Run:  PYTHONPATH=src python examples/streaming_logreg_dmb.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import AveragingConfig, GovernorConfig, StreamConfig
from repro.configs.paper_logreg import FIG6
from repro.core import dmb, problems
from repro.core.rates import dmb_stepsize
from repro.data.synthetic import make_logreg_stream
from repro.train.driver import EngineConfig, StreamingDriver

stream = make_logreg_stream(FIG6)
grad = lambda w, x, y: problems.logistic_grad(w, x, y)
metric = lambda w: jnp.sum((w - stream.w_star) ** 2)
w0 = jnp.zeros(FIG6.dim + 1)

print("Fig 6(a): resourceful regime, error vs B at t' = 1e5 samples")
for B in (1, 10, 100, 1000):
    c = {1: 0.1, 10: 0.3, 100: 2.0, 1000: 8.0}[B]
    res = dmb.run_dmb(grad, stream.draw, w0, N=min(10, B), B=B,
                      steps=max(1, 100_000 // B),
                      stepsize=lambda t: c / jnp.sqrt(t), trace_metric=metric)
    print(f"  B={B:5d}  ||w-w*||^2 = {float(res.trace_metric[-1]):.5f}")

print("Fig 6(b): under-provisioned regime, (N,B)=(10,500), mu discards")
for mu in (0, 100, 500, 2000):
    res = dmb.run_dmb(grad, stream.draw, w0, N=10, B=500, mu=mu, steps=200,
                      stepsize=lambda t: 2.0 / jnp.sqrt(t), trace_metric=metric,
                      seed=1)
    print(f"  mu={mu:5d}  ||w-w*||^2 = {float(res.trace_metric[-1]):.5f} "
          f"(t' arrived = {int(res.trace_t_prime[-1])})")

# Theorem 4's prescribed stepsize is also available:
print(f"Thm-4 stepsize at t=100 (L=1, sigma=1, D_W=5): "
      f"{dmb_stepsize(100, 1.0, 1.0, 5.0):.4f}")

# ---------------------------------------------------------------------------
# DMB on the full streaming engine with the ADAPTIVE governor: the splitter
# deals B per round, a K-round superstep scans on device, and the closed loop
# may move B between pre-compiled buckets (plan swap, zero retrace) while the
# online estimator replaces the config's R_c with a measured one.
# ---------------------------------------------------------------------------
print("DMB on the streaming engine (adaptive-B governor, N=10):")
N = 10


@dataclasses.dataclass(frozen=True)
class _Carrier:  # the driver only reads .averaging and .stream
    averaging: AveragingConfig
    stream: StreamConfig


run_cfg = _Carrier(
    averaging=AveragingConfig(mode="exact", rounds=1),
    stream=StreamConfig(streaming_rate=1e4, processing_rate=1e6,
                        comms_rate=1e6))

w_star_np = np.asarray(stream.w_star, np.float32)


def sample_fn(rng: np.random.Generator, n: int):
    # host-side twin of the Fig. 6 logistic-link stream (numpy entropy so the
    # prefetch thread never touches the device PRNG)
    x = rng.standard_normal((n, FIG6.dim), dtype=np.float32)
    p = 1.0 / (1.0 + np.exp(-(x @ w_star_np[:-1] + w_star_np[-1])))
    y = np.where(rng.random(n) < p, 1.0, -1.0).astype(np.float32)
    return {"x": x, "y": y}


def dmb_superstep(state, batches):
    """K rounds of Alg. 1 (exact averaging): per-node grads, jnp.mean, one
    projected step; B/N is read from the batch shape, so one closure serves
    every bucket of the ladder."""

    def round_fn(carry, batch):
        w, t = carry
        t = t + 1
        x, y = batch["x"], batch["y"]
        xn = x.reshape(N, x.shape[0] // N, -1)
        yn = y.reshape(N, y.shape[0] // N)
        g = jnp.mean(jax.vmap(lambda a, b: problems.logistic_grad(w, a, b))(
            xn, yn), axis=0)
        w = problems.project_ball(w - 2.0 / jnp.sqrt(t) * g, 10.0)
        return (w, t), {"err": jnp.sum((w - stream.w_star) ** 2)}

    return jax.lax.scan(round_fn, state, batches)


state = (jnp.zeros(FIG6.dim + 1), jnp.zeros((), jnp.int32))
gov = GovernorConfig(buckets=(50, 100, 200), hysteresis=2)
with StreamingDriver(run_cfg, None, state, sample_fn,
                     superstep_fn=dmb_superstep, n_nodes=N, batch=100,
                     engine=EngineConfig(superstep=8, prefetch_depth=2,
                                         governor=gov)) as drv:
    state, history = drv.run(20)
    for rec in history:
        decision = ""
        if "bucket_switch" in rec:
            a, b = rec["bucket_switch"]
            decision += f"  SWITCH B:{a}->{b}"
        if "est_Rc" in rec:
            rc = rec["est_Rc"]
            decision += ("  est_Rc=inf" if rc <= 0
                         else f"  est_Rc={rc:.3g}")
        if rec["superstep"] % 4 == 0 or decision:
            p = rec.get("replanned", rec["plan"])
            print(f"  superstep {rec['superstep']:3d}  B={rec['bucket']:4d} "
                  f"mu={p.mu:4d} {p.regime:17s} "
                  f"||w-w*||^2={rec['metrics']['err']:.4f}{decision}")
    print(f"  buckets compiled: {list(drv.compiled_buckets)} "
          f"(ladder {list(drv.ladder.buckets)})")
