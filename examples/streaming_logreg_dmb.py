"""Paper Section IV-B (Fig. 6): the DMB algorithm training a binary linear
classifier from a fast synthetic stream, in both the resourceful and the
under-provisioned (mu > 0 discards) regimes.

Run:  PYTHONPATH=src python examples/streaming_logreg_dmb.py
"""
import jax.numpy as jnp

from repro.configs.paper_logreg import FIG6
from repro.core import dmb, problems
from repro.core.rates import dmb_stepsize
from repro.data.synthetic import make_logreg_stream

stream = make_logreg_stream(FIG6)
grad = lambda w, x, y: problems.logistic_grad(w, x, y)
metric = lambda w: jnp.sum((w - stream.w_star) ** 2)
w0 = jnp.zeros(FIG6.dim + 1)

print("Fig 6(a): resourceful regime, error vs B at t' = 1e5 samples")
for B in (1, 10, 100, 1000):
    c = {1: 0.1, 10: 0.3, 100: 2.0, 1000: 8.0}[B]
    res = dmb.run_dmb(grad, stream.draw, w0, N=min(10, B), B=B,
                      steps=max(1, 100_000 // B),
                      stepsize=lambda t: c / jnp.sqrt(t), trace_metric=metric)
    print(f"  B={B:5d}  ||w-w*||^2 = {float(res.trace_metric[-1]):.5f}")

print("Fig 6(b): under-provisioned regime, (N,B)=(10,500), mu discards")
for mu in (0, 100, 500, 2000):
    res = dmb.run_dmb(grad, stream.draw, w0, N=10, B=500, mu=mu, steps=200,
                      stepsize=lambda t: 2.0 / jnp.sqrt(t), trace_metric=metric,
                      seed=1)
    print(f"  mu={mu:5d}  ||w-w*||^2 = {float(res.trace_metric[-1]):.5f} "
          f"(t' arrived = {int(res.trace_t_prime[-1])})")

# Theorem 4's prescribed stepsize is also available:
print(f"Thm-4 stepsize at t=100 (L=1, sigma=1, D_W=5): "
      f"{dmb_stepsize(100, 1.0, 1.0, 5.0):.4f}")
