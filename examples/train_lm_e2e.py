"""End-to-end driver: train a ~100M-parameter decoder LM for a few hundred
steps from a governed synthetic token stream, with the paper's averaging mode
selectable. This is the paper's framework at LM scale: the data axis carries
the N streaming nodes, the governor enforces (B, mu) from the rate model.

Defaults are sized for a CPU container (--dim 512 --layers 8 ~ 60M params,
--steps 200); pass --dim 768 --layers 12 for the full ~125M run on real
hardware.

Run:  PYTHONPATH=src python examples/train_lm_e2e.py --steps 200
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import AveragingConfig, RunConfig, SHAPES, StreamConfig
from repro.data.lm import MarkovTokenStream
from repro.data.pipeline import StreamingPipeline
from repro.launch.mesh import make_host_mesh, n_data_nodes
from repro.launch.sharding import activation_rules
from repro.models.common import mesh_rules
from repro.train import checkpoint as ckpt
from repro.train.trainer import (build_train_step, init_state, make_node_batch,
                                 replicate_for_nodes)

ap = argparse.ArgumentParser()
ap.add_argument("--dim", type=int, default=512)
ap.add_argument("--layers", type=int, default=8)
ap.add_argument("--vocab", type=int, default=8192)
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=256)
ap.add_argument("--averaging", default="exact")
ap.add_argument("--rounds", type=int, default=4)
ap.add_argument("--checkpoint", default="")
args = ap.parse_args()

base = get_config("granite-8b")  # llama-style family
cfg = dataclasses.replace(
    base, num_layers=args.layers, d_model=args.dim,
    num_heads=max(4, args.dim // 64), num_kv_heads=max(2, args.dim // 128),
    d_ff=4 * args.dim, vocab_size=args.vocab, head_dim=0,
    name=f"llama-style-{args.dim}d{args.layers}L")
print(f"model: {cfg.name}, {cfg.param_count() / 1e6:.1f}M params")

run = RunConfig(model=cfg, shape=SHAPES["train_4k"],
                averaging=AveragingConfig(args.averaging, args.rounds),
                stream=StreamConfig(),  # ungoverned: consume everything
                optimizer="adam", learning_rate=3e-4, param_dtype="float32")
mesh = make_host_mesh()
n_nodes = n_data_nodes(mesh)
decentralized = args.averaging != "exact"

data = MarkovTokenStream(cfg.vocab_size, seed=0)
pipe = StreamingPipeline(
    lambda rng, n: (lambda t: {"tokens": t[:, :-1], "labels": t[:, 1:]})(
        data.sample(rng, n, args.seq + 1)),
    run.stream, n_nodes, args.rounds, batch=args.batch)

with mesh_rules(mesh, activation_rules(mesh, run.shape, decentralized)):
    state = init_state(run, jax.random.PRNGKey(0))
    if decentralized:
        state = replicate_for_nodes(state, n_nodes)
    step, _ = build_train_step(run, mesh)
    step = jax.jit(step, donate_argnums=0)
    t0, first_loss = time.time(), None
    for i, batch in zip(range(args.steps), pipe):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        if decentralized:
            batch = make_node_batch(batch, n_nodes)
        state, metrics = step(state, batch)
        if first_loss is None:
            first_loss = float(metrics["loss"])
        if i % 20 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {float(metrics['loss']):.4f}  "
                  f"tok/s {(i + 1) * args.batch * args.seq / (time.time() - t0):.0f}",
                  flush=True)
final = float(metrics["loss"])
print(f"loss: {first_loss:.3f} -> {final:.3f} over {args.steps} steps")
assert final < first_loss, "e2e training must learn"
if args.checkpoint:
    ckpt.save(args.checkpoint, state, step=args.steps, meta={"model": cfg.name})
    print("checkpoint ->", args.checkpoint)
